"""Render a run's observability artifacts (``python -m repro.obs``).

A *run dir* is whatever ``launch/prune.py --ckpt-dir`` (or any caller of
``obs.save_run_dir``) left behind:

* ``obs/spans.jsonl`` + ``obs/metrics.jsonl`` + ``obs/trace.json`` —
  written by ``repro.obs.save_run_dir``;
* ``run_summary.json`` — the scheduler's run-level telemetry
  (``core/driver.py``);
* ``unit_*/MANIFEST.json`` — per-unit checkpoints whose ``extra``
  carries the scheduler telemetry (worker / seconds / attempts) and the
  per-operator solver reports.

``summarize_run`` merges all three into one dict; ``render_text`` prints
it.  Everything degrades gracefully — a serve-only metrics file, a
prune run without obs enabled, or a bare spans file each produce a
partial summary rather than an error.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

from repro.obs import OBS_SUBDIR
from repro.obs import metrics as metrics_lib
from repro.obs import spans as spans_lib


def _load_json(path: str) -> Optional[Dict[str, Any]]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def unit_telemetry(run_dir: str) -> List[Dict[str, Any]]:
    """Scheduler telemetry from every ``unit_*`` checkpoint MANIFEST."""
    out: List[Dict[str, Any]] = []
    for mpath in sorted(glob.glob(os.path.join(run_dir, "unit_*",
                                               "MANIFEST.json"))):
        manifest = _load_json(mpath)
        if not manifest:
            continue
        extra = manifest.get("extra") or {}
        tel = dict(extra.get("telemetry") or {})
        tel["unit"] = os.path.basename(os.path.dirname(mpath))[len("unit_"):]
        tel["ops"] = len(extra.get("reports") or [])
        out.append(tel)
    return out


def span_rollup(spans: List[spans_lib.Span]) -> Dict[str, Dict[str, Any]]:
    """Per-name span aggregate: count, total / max wall seconds."""
    agg: Dict[str, Dict[str, Any]] = {}
    for sp in spans:
        a = agg.setdefault(sp.name, {"count": 0, "total_s": 0.0, "max_s": 0.0})
        a["count"] += 1
        a["total_s"] += sp.dur
        a["max_s"] = max(a["max_s"], sp.dur)
    return dict(sorted(agg.items()))


def summarize_run(run_dir: str) -> Dict[str, Any]:
    obs_dir = os.path.join(run_dir, OBS_SUBDIR)
    summary: Dict[str, Any] = {"run_dir": run_dir}

    spath = os.path.join(obs_dir, "spans.jsonl")
    if os.path.exists(spath):
        spans = spans_lib.load_jsonl(spath)
        summary["spans"] = span_rollup(spans)
        summary["num_spans"] = len(spans)

    mpath = os.path.join(obs_dir, "metrics.jsonl")
    if os.path.exists(mpath):
        reg = metrics_lib.MetricsRegistry.load_jsonl(mpath)
        summary["metrics"] = reg.snapshot()

    rs = _load_json(os.path.join(run_dir, "run_summary.json"))
    if rs is not None:
        summary["run_summary"] = rs

    units = unit_telemetry(run_dir)
    if units:
        summary["units"] = units
    return summary


def _fmt_seconds(s: Optional[float]) -> str:
    if s is None:
        return "-"
    if s < 1e-3:
        return f"{s * 1e6:.0f}us"
    if s < 1.0:
        return f"{s * 1e3:.1f}ms"
    return f"{s:.2f}s"


def render_text(summary: Dict[str, Any]) -> str:
    lines: List[str] = [f"run: {summary['run_dir']}"]

    rs = summary.get("run_summary", {}).get("run_summary") \
        or summary.get("run_summary")
    if rs:
        lines.append("\n== scheduler run summary ==")
        lines.append(f"  total solver seconds: "
                     f"{rs.get('total_solver_seconds', 0.0):.2f}")
        slow = rs.get("slowest_unit")
        if slow:
            lines.append(f"  slowest unit: {slow['unit']} "
                         f"({_fmt_seconds(slow['seconds'])})")
        hist = rs.get("attempts_histogram") or {}
        if hist:
            parts = ", ".join(f"{a} attempt(s): {n} unit(s)"
                              for a, n in sorted(hist.items()))
            lines.append(f"  attempts: {parts}")

    units = summary.get("units")
    if units:
        lines.append("\n== unit telemetry ==")
        lines.append(f"  {'unit':<16} {'worker':>6} {'attempts':>8} "
                     f"{'seconds':>9} {'ops':>4}")
        for u in units:
            lines.append(f"  {u['unit']:<16} {u.get('worker', '-')!s:>6} "
                         f"{u.get('attempts', '-')!s:>8} "
                         f"{_fmt_seconds(u.get('seconds')):>9} "
                         f"{u['ops']:>4}")

    met = summary.get("metrics")
    if met and any(n.startswith("serve.") for n in met):
        lines.append("\n== serve SLO ==")

        def _hq(name: str, q: float) -> Optional[float]:
            m = met.get(name)
            if not m or m.get("kind") != "histogram":
                return None
            h = metrics_lib.Histogram.from_dict(m)
            return h.quantile(q) if h.total else None

        def _cv(name: str) -> int:
            m = met.get(name)
            return int(m["value"]) if m and m.get("kind") == "counter" else 0

        ttft50, ttft99 = _hq("serve.ttft_s", 0.5), _hq("serve.ttft_s", 0.99)
        itl50, itl99 = (_hq("serve.inter_token_s", 0.5),
                        _hq("serve.inter_token_s", 0.99))
        if ttft50 is not None:
            lines.append(f"  ttft        p50 {_fmt_seconds(ttft50)}  "
                         f"p99 {_fmt_seconds(ttft99)}")
        if itl50 is not None:
            lines.append(f"  inter-token p50 {_fmt_seconds(itl50)}  "
                         f"p99 {_fmt_seconds(itl99)}")
        hits, misses = _cv("serve.prefix_hits"), _cv("serve.prefix_misses")
        if hits + misses:
            lines.append(f"  prefix cache: {hits}/{hits + misses} lookups "
                         f"hit ({_cv('serve.prefix_hit_tokens')} tokens "
                         f"reused, {_cv('serve.prefix_evicted_blocks')} "
                         f"blocks evicted)")
        if _cv("serve.prefill_chunks"):
            lines.append(f"  chunked prefill: "
                         f"{_cv('serve.prefill_chunks')} chunks")
        if _cv("serve.preemptions"):
            lines.append(f"  preemptions: {_cv('serve.preemptions')}")
        waits = sorted(n for n in met
                       if n.startswith("serve.admission_wait_s.p"))
        wparts = []
        for n in waits:
            q50 = _hq(n, 0.5)
            if q50 is not None:
                wparts.append(f"{n.rsplit('.', 1)[1]} {_fmt_seconds(q50)}")
        if wparts:
            lines.append("  admission wait p50: " + ", ".join(wparts))

    if met:
        lines.append("\n== metrics ==")
        for name, m in met.items():
            kind = m["kind"]
            if kind == "counter":
                lines.append(f"  {name:<32} {m['value']}")
            elif kind == "gauge":
                lines.append(f"  {name:<32} {m['value']:.4g} "
                             f"(min {m['min']:.4g}, max {m['max']:.4g})"
                             if m.get("n") else f"  {name:<32} (unset)")
            elif kind == "histogram":
                h = metrics_lib.Histogram.from_dict(m)
                # latency histograms by convention carry a `_s` suffix
                # (possibly before a per-class tag, e.g. `_s.p0`);
                # everything else (iteration counts, depths, fractions)
                # prints as plain numbers
                fmt = _fmt_seconds if name.endswith("_s") or "_s." in name \
                    else (lambda v: "-" if v is None else f"{v:.4g}")
                lines.append(
                    f"  {name:<32} n={h.total} mean={fmt(h.mean)} "
                    f"p50={fmt(h.quantile(0.5))} "
                    f"p99={fmt(h.quantile(0.99))} "
                    f"max={fmt(None if h.total == 0 else h.vmax)}")
            elif kind == "series":
                lines.append(f"  {name:<32} {len(m['records'])} record(s)")

    sps = summary.get("spans")
    if sps:
        lines.append("\n== spans (top by total wall) ==")
        top = sorted(sps.items(), key=lambda kv: -kv[1]["total_s"])[:20]
        for name, a in top:
            lines.append(f"  {name:<32} x{a['count']:<6} "
                         f"total {_fmt_seconds(a['total_s'])}, "
                         f"max {_fmt_seconds(a['max_s'])}")
        lines.append(f"  ({summary.get('num_spans', 0)} spans retained; "
                     f"export with `python -m repro.obs trace <run_dir>`)")

    if len(lines) == 1:
        lines.append("(no observability artifacts found — run with "
                     "obs enabled, e.g. launch/serve.py --metrics-out or "
                     "launch/prune.py --ckpt-dir)")
    return "\n".join(lines)
