"""repro.obs — unified tracing + metrics (DESIGN.md §14).

One process-global :class:`~repro.obs.spans.SpanRecorder` and one
:class:`~repro.obs.metrics.MetricsRegistry`, toggled by
:func:`enable`/:func:`disable`.  Instrumentation sites follow two rules:

* **spans** go through :func:`span` — it returns a shared no-op context
  manager while disabled, so span sites cost one function call;
* **metrics** in hot loops fetch their instruments ONCE at construction
  behind an ``enabled()`` check (see ``serve/batcher.py``) so the
  per-tick cost is a guarded attribute access + a bisect, never a
  registry lookup; the registry itself is reached via :func:`registry`.

Recording never touches device values before they are already on the
host: solver convergence traces come out of the fused while_loops as
device arrays and are transferred once post-solve (the JAX003 rule and
its OBS001 sibling keep this honest).

``save_run_dir(run_dir)`` persists everything next to the checkpoint
store's artifacts: ``<run_dir>/obs/spans.jsonl``, ``metrics.jsonl`` and
a Perfetto-loadable ``trace.json``.  ``python -m repro.obs report`` (see
``report.py``) renders a saved run.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.obs import metrics as metrics_lib
from repro.obs import spans as spans_lib
from repro.obs.metrics import (COUNT_BUCKETS, FRACTION_BUCKETS,
                               LATENCY_BUCKETS_S, MetricsRegistry)
from repro.obs.spans import NULL_SPAN, Span, SpanRecorder

__all__ = ["enable", "disable", "enabled", "span", "registry", "recorder",
           "save_run_dir", "MetricsRegistry", "SpanRecorder", "Span",
           "LATENCY_BUCKETS_S", "COUNT_BUCKETS", "FRACTION_BUCKETS",
           "OBS_SUBDIR"]

#: subdirectory of a run dir holding the persisted obs artifacts
OBS_SUBDIR = "obs"

_enabled = False
_recorder = SpanRecorder()
_registry = MetricsRegistry()


def enable(capacity: int = 4096, reset: bool = True) -> None:
    """Turn recording on.  ``reset`` (default) starts from a fresh
    recorder/registry so back-to-back runs don't bleed into each other
    (benchmarks interleave instrumented and bare runs)."""
    global _enabled, _recorder, _registry
    if reset or _recorder.capacity != capacity:
        _recorder = SpanRecorder(capacity)
        _registry = MetricsRegistry()
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def span(name: str, **attrs):
    """A context manager timing ``name``; no-op while disabled."""
    if not _enabled:
        return NULL_SPAN
    return _recorder.span(name, **attrs)


# named `registry` (not `metrics`) so the accessor never shadows the
# `repro.obs.metrics` submodule attribute on the package
def registry() -> MetricsRegistry:
    return _registry


def recorder() -> SpanRecorder:
    return _recorder


def save_run_dir(run_dir: str, subdir: str = OBS_SUBDIR) -> Optional[str]:
    """Persist spans + metrics + Perfetto trace under ``run_dir/obs/``.
    Returns the obs directory, or None when nothing was recorded."""
    if _recorder.total == 0 and len(_registry) == 0:
        return None
    out = os.path.join(run_dir, subdir)
    os.makedirs(out, exist_ok=True)
    sps = _recorder.spans()
    spans_lib.dump_jsonl(sps, os.path.join(out, "spans.jsonl"))
    _registry.dump_jsonl(os.path.join(out, "metrics.jsonl"))
    spans_lib.export_perfetto(sps, os.path.join(out, "trace.json"))
    return out
