"""Nestable wall-clock spans in a fixed-capacity ring buffer.

A :class:`SpanRecorder` hands out context managers::

    with recorder.span("prune.unit", unit="layer_3"):
        ...

Each finished span is one immutable :class:`Span` appended to a ring of
``capacity`` entries (old spans are overwritten, the total count keeps
climbing), so a long serve run records the *recent* timeline at a bounded
memory cost.  Nesting is tracked per thread — the scheduler's worker
threads each get their own stack, and their spans land on separate
Perfetto tracks via ``tid``.

Overhead budget: a span costs two ``time.perf_counter()`` calls, one
lock-guarded id allocation, one lock-guarded ring write and one small
object — single-digit microseconds, against serve decode steps of
hundreds of microseconds (gated ≤2% in benchmarks/serve_bench.py).
The process-global recorder in ``repro.obs`` additionally returns a
shared no-op context manager when observability is disabled, so
uninstrumented runs pay only a function call per span site.

Persistence: ``dump_jsonl`` writes one JSON object per span;
``export_perfetto`` emits the Chrome trace-event format
(``{"traceEvents": [{"ph": "X", ...}]}``, timestamps in microseconds)
that chrome://tracing and https://ui.perfetto.dev load directly.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished span.  ``t0`` is seconds from the recorder's epoch
    (NOT unix time — see ``SpanRecorder.epoch_unix``)."""

    index: int              # allocation order, unique within a recorder
    parent: int             # enclosing span's index, -1 at top level
    name: str               # dotted, e.g. "prune.unit"
    t0: float               # start, seconds from recorder epoch
    dur: float              # wall seconds
    tid: int                # thread ident of the recording thread
    depth: int              # nesting depth within its thread (0 = top)
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Span":
        return cls(index=int(d["index"]), parent=int(d["parent"]),
                   name=str(d["name"]), t0=float(d["t0"]),
                   dur=float(d["dur"]), tid=int(d["tid"]),
                   depth=int(d["depth"]), attrs=dict(d.get("attrs") or {}))


class _NullSpan:
    """Shared no-op context manager returned when obs is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _ActiveSpan:
    """Context manager for one live span (see :meth:`SpanRecorder.span`)."""

    __slots__ = ("_rec", "name", "attrs", "_index", "_parent", "_depth", "_t0")

    def __init__(self, rec: "SpanRecorder", name: str,
                 attrs: Dict[str, Any]) -> None:
        self._rec = rec
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_ActiveSpan":
        rec = self._rec
        with rec._lock:
            self._index = rec._next_index
            rec._next_index += 1
        stack = rec._stack()
        self._parent = stack[-1] if stack else -1
        self._depth = len(stack)
        stack.append(self._index)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, *exc) -> bool:
        dur = time.perf_counter() - self._t0
        rec = self._rec
        rec._stack().pop()
        attrs = self.attrs
        if exc_type is not None:
            attrs = dict(attrs, error=exc_type.__name__)
        rec._record(Span(
            index=self._index, parent=self._parent, name=self.name,
            t0=self._t0 - rec.epoch, dur=dur,
            tid=threading.get_ident(), depth=self._depth, attrs=attrs))
        return False


class SpanRecorder:
    """Fixed-capacity ring of finished spans; thread-safe."""

    def __init__(self, capacity: int = 4096) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._ring: List[Optional[Span]] = [None] * capacity
        self._count = 0           # total spans ever recorded
        self._next_index = 0
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.epoch = time.perf_counter()
        self.epoch_unix = time.time()   # for correlating with log lines

    def _stack(self) -> List[int]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def span(self, name: str, **attrs: Any) -> _ActiveSpan:
        return _ActiveSpan(self, name, attrs)

    def _record(self, span: Span) -> None:
        with self._lock:
            self._ring[self._count % self.capacity] = span
            self._count += 1

    @property
    def total(self) -> int:
        """Spans recorded over the recorder's lifetime (>= len(spans()))."""
        return self._count

    def spans(self) -> List[Span]:
        """The retained spans, oldest first (last ``capacity`` recorded)."""
        with self._lock:
            n = min(self._count, self.capacity)
            start = self._count - n
            return [self._ring[(start + i) % self.capacity]  # type: ignore
                    for i in range(n)]

    def clear(self) -> None:
        with self._lock:
            self._ring = [None] * self.capacity
            self._count = 0

    def dump_jsonl(self, path: str) -> None:
        dump_jsonl(self.spans(), path)


# ---------------------------------------------------------------------------
# persistence / export
# ---------------------------------------------------------------------------
def dump_jsonl(spans: List[Span], path: str) -> None:
    """One JSON object per line; round-trips through :func:`load_jsonl`."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        for sp in spans:
            f.write(json.dumps(sp.to_dict(), default=str) + "\n")


def load_jsonl(path: str) -> List[Span]:
    out: List[Span] = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(Span.from_dict(json.loads(line)))
    return out


def perfetto_events(spans: List[Span],
                    pid: Optional[int] = None) -> List[Dict[str, Any]]:
    """Chrome trace-event list: one complete ("X") event per span plus
    thread_name metadata.  Thread idents are compacted to small track
    ids so the Perfetto timeline stays readable."""
    pid = os.getpid() if pid is None else pid
    tids: Dict[int, int] = {}
    events: List[Dict[str, Any]] = []
    for sp in spans:
        tid = tids.setdefault(sp.tid, len(tids))
        events.append({
            "ph": "X", "name": sp.name,
            "cat": sp.name.split(".", 1)[0],
            "ts": sp.t0 * 1e6, "dur": sp.dur * 1e6,
            "pid": pid, "tid": tid,
            "args": {k: (v if isinstance(v, (int, float, bool, str)
                              or v is None) else str(v))
                     for k, v in sp.attrs.items()},
        })
    for ident, tid in tids.items():
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": f"thread-{ident}"}})
    return events


def export_perfetto(spans: List[Span], path: str,
                    pid: Optional[int] = None) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"traceEvents": perfetto_events(spans, pid),
                   "displayTimeUnit": "ms"}, f)
