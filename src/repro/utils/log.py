"""Minimal structured logger (stdlib logging, single format, env-tunable)."""
from __future__ import annotations

import logging
import os
import sys
import time


_CONFIGURED = False


def _configure() -> None:
    global _CONFIGURED
    if _CONFIGURED:
        return
    level = os.environ.get("REPRO_LOG", "INFO").upper()
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(logging.Formatter("%(asctime)s %(name)s %(levelname)s %(message)s", "%H:%M:%S"))
    root = logging.getLogger("repro")
    root.setLevel(level)
    root.addHandler(handler)
    root.propagate = False
    _CONFIGURED = True


def get_logger(name: str) -> logging.Logger:
    _configure()
    return logging.getLogger(f"repro.{name}")


class Timer:
    """Context-manager wall timer: ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        self.seconds = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self._t0
