"""Minimal structured logger (stdlib logging, single format, env-tunable)."""
from __future__ import annotations

import logging
import os
import sys
import time


#: marker attribute stamped on our handler so re-imports of this module
#: (pytest reloads, importlib.reload) recognize an already-configured
#: "repro" logger instead of stacking a second handler onto it — a
#: module-global guard resets with the module and duplicated every line
_HANDLER_MARK = "_repro_handler"


def _resolve_level() -> int:
    """``REPRO_LOG`` -> logging level; invalid values fall back to INFO
    with a one-line warning instead of crashing (or silently passing a
    bogus string level through to logging)."""
    raw = os.environ.get("REPRO_LOG", "INFO").upper()
    level = logging.getLevelName(raw)
    if isinstance(level, int):
        return level
    print(f"repro: invalid REPRO_LOG={raw!r}, falling back to INFO",
          file=sys.stderr)
    return logging.INFO


def _configure() -> None:
    root = logging.getLogger("repro")
    if any(getattr(h, _HANDLER_MARK, False) for h in root.handlers):
        return
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(logging.Formatter("%(asctime)s %(name)s %(levelname)s %(message)s", "%H:%M:%S"))
    setattr(handler, _HANDLER_MARK, True)
    root.setLevel(_resolve_level())
    root.addHandler(handler)
    root.propagate = False


def get_logger(name: str) -> logging.Logger:
    _configure()
    return logging.getLogger(f"repro.{name}")


class Timer:
    """Context-manager wall timer: ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        self.seconds = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self._t0
