"""Small shared utilities: pytree helpers, logging, timing, rng streams."""
from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_index,
    tree_stack,
    tree_unstack,
    flatten_with_paths,
    get_path,
    set_path,
)
from repro.utils.log import get_logger

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_index",
    "tree_stack",
    "tree_unstack",
    "flatten_with_paths",
    "get_path",
    "set_path",
    "get_logger",
]
