"""Shims over JAX API differences between the versions we support.

The mesh-context API moved around 0.5.x: ``jax.sharding.get_abstract_mesh``
/ ``set_mesh`` exist on new JAX, while 0.4.x exposes the abstract mesh only
under ``jax._src.mesh`` and tracks the physical mesh via
``thread_resources``.  Model code calls these helpers instead of either API
directly.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax


def ambient_mesh() -> Optional[object]:
    """The ambient (abstract or physical) device mesh, or None.

    Returns something with ``.axis_names`` and a dict-like ``.shape``
    (both ``jax.sharding.Mesh`` and ``AbstractMesh`` qualify), usable as
    the ``mesh=`` argument of ``shard_map``.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        try:
            from jax._src import mesh as _mesh_lib
            get = getattr(_mesh_lib, "get_abstract_mesh", None)
        except ImportError:
            get = None
    if get is not None:
        try:
            mesh = get()
            if mesh is not None and getattr(mesh, "axis_names", ()):
                return mesh
        except Exception:  # noqa: BLE001 — fall through to the physical mesh
            pass
    try:
        from jax.interpreters import pxla
        phys = pxla.thread_resources.env.physical_mesh
        if phys is not None and not phys.empty:
            return phys
    except Exception:  # noqa: BLE001
        pass
    return None


def pvary(x, axes):
    """``jax.lax.pvary`` when it exists (the vma type system of newer JAX);
    identity on 0.4.x, which has no varying-manifest annotations."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axes) if fn is not None else x


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version:
    0.4.x returns a one-element list of per-device dicts, newer JAX the
    dict itself (and None is possible on exotic backends)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def force_host_devices_flags(devices: int, base: Optional[str] = None) -> str:
    """XLA_FLAGS value forcing ``devices`` fake host devices, REPLACING
    any force-count flag already in ``base`` (default: the current env).

    The last duplicated XLA flag wins, so naively prepending lets an
    inherited export override the requested count — every subprocess
    spawner that fakes a device count (distributed test cases, the
    mesh-gram bench children, CLI tests) must route through this.
    """
    import os

    kept = [f for f in (os.environ.get("XLA_FLAGS", "") if base is None
                        else base).split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    return " ".join(
        [f"--xla_force_host_platform_device_count={devices}"] + kept)


def set_mesh(mesh):
    """``jax.sharding.set_mesh(mesh)`` when available, else a no-op context
    (on 0.4.x the enclosing ``with mesh:`` already installs the physical
    mesh that :func:`ambient_mesh` falls back to)."""
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return contextlib.nullcontext()
