"""Pytree utilities used across the framework.

Params everywhere in repro are nested dicts of jnp arrays.  Paths are
"/"-joined key strings, e.g. ``layers/attn/wq``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


def tree_count(tree: Tree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Tree) -> int:
    """Total bytes of a pytree of arrays (respects per-leaf dtype)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_index(tree: Tree, i: int) -> Tree:
    """Index the leading axis of every leaf (layer-stacked params -> one layer)."""
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def tree_stack(trees: List[Tree]) -> Tree:
    """Stack a list of identically-structured trees along a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Tree, n: int) -> List[Tree]:
    return [tree_index(tree, i) for i in range(n)]


def _flatten(prefix: str, node: Tree, out: List[Tuple[str, Any]]) -> None:
    if isinstance(node, dict):
        for k in sorted(node.keys()):
            _flatten(f"{prefix}/{k}" if prefix else str(k), node[k], out)
    elif isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            _flatten(f"{prefix}/{i}" if prefix else str(i), v, out)
    elif node is None:
        return
    else:
        out.append((prefix, node))


def flatten_with_paths(tree: Tree) -> List[Tuple[str, Any]]:
    """Deterministic (path, leaf) list; dict keys sorted."""
    out: List[Tuple[str, Any]] = []
    _flatten("", tree, out)
    return out


def get_path(tree: Tree, path: str) -> Any:
    node = tree
    for k in path.split("/"):
        if isinstance(node, (list, tuple)):
            node = node[int(k)]
        else:
            node = node[k]
    return node


def set_path(tree: Tree, path: str, value: Any) -> Tree:
    """Functionally replace the leaf at ``path`` (returns a new tree; shares
    untouched subtrees)."""
    keys = path.split("/")

    def rec(node: Tree, i: int) -> Tree:
        if i == len(keys):
            return value
        k = keys[i]
        if isinstance(node, dict):
            new = dict(node)
            new[k] = rec(node[k], i + 1)
            return new
        if isinstance(node, (list, tuple)):
            idx = int(k)
            new_list = list(node)
            new_list[idx] = rec(node[idx], i + 1)
            return type(node)(new_list) if isinstance(node, tuple) else new_list
        raise KeyError(f"cannot descend into leaf at {'/'.join(keys[:i])}")

    return rec(tree, 0)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Tree) -> Tree:
    """Map ``fn(path, leaf) -> leaf`` over a nested-dict tree."""

    def rec(prefix: str, node: Tree) -> Tree:
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else str(k), v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [rec(f"{prefix}/{i}" if prefix else str(i), v) for i, v in enumerate(node)]
            return type(node)(seq) if isinstance(node, tuple) else seq
        if node is None:
            return None
        return fn(prefix, node)

    return rec("", tree)


def iter_leaves_with_paths(tree: Tree) -> Iterator[Tuple[str, Any]]:
    yield from flatten_with_paths(tree)


def tree_allclose(a: Tree, b: Tree, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol) for x, y in zip(la, lb))
