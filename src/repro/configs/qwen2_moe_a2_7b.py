"""qwen2-moe-a2.7b — hf:Qwen/Qwen1.5-MoE-A2.7B [hf].

24L d_model=2048 16H (kv=16) vocab=151936; MoE: 60 routed experts top-4
(expert_ff=1408) + one fused shared expert (4x1408=5632) with a sigmoid
gate; router probs NOT renormalized after top-k (qwen flavor); qkv bias.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-moe-a2.7b", family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=60, top_k=4, expert_ff=1408,
                      num_shared=4, shared_ff=5632, norm_topk=False),
        attn_impl="flash",
        norm="rmsnorm", act="silu", ce_chunk=512, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=32,
        vocab=256,
        moe=MoEConfig(num_experts=8, top_k=2, expert_ff=32,
                      num_shared=1, shared_ff=64, norm_topk=False),
        param_dtype="float32", compute_dtype="float32", remat=False,
        ce_chunk=0, max_seq=64)
