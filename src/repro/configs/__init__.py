"""Architecture configs: one module per assigned arch (+ the paper's own
OPT-proxy family).  Each exports ``config()`` and ``smoke_config()``."""
from repro.configs.base import (ALL_ARCHS, SHAPES, ModelConfig, ShapeSpec,
                                cells, shape_applicable)

__all__ = ["ALL_ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "cells",
           "shape_applicable"]
