"""minicpm-2b — WSD schedule, muP-style scaling, arXiv:2404.06395 [hf].

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.  muP knobs per the
MiniCPM paper: emb_scale=12, residual branches scaled by 1.4/sqrt(L),
logits scaled by dim_model_base/d_model = 256/2304; tied embeddings.
"""
import math

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="minicpm-2b", family="dense",
        source="arXiv:2404.06395; hf",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab=122753,
        tie_embeddings=True,
        emb_scale=12.0, residual_scale=1.4 / math.sqrt(40),
        logit_scale=256.0 / 2304.0,
        attn_impl="flash",
        norm="rmsnorm", act="silu", ce_chunk=512, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab=256, residual_scale=1.4 / math.sqrt(2),
        logit_scale=256.0 / 64.0,
        param_dtype="float32", compute_dtype="float32", remat=False,
        ce_chunk=0, max_seq=64)
