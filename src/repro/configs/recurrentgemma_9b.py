"""recurrentgemma-9b — RG-LRU + local attention 1:2, arXiv:2402.19427 [unverified].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.  Block pattern
(recurrent, recurrent, attention) with window 2048; GeGLU MLPs; Gemma
embedding scaling and a 30.0 final-logit softcap.  Sub-quadratic: runs
long_500k (O(1) recurrent state + windowed KV).
"""
import math

from repro.configs.base import ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="recurrentgemma-9b", family="hybrid",
        source="arXiv:2402.19427; unverified",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        d_ff=12288, vocab=256000, window=2048,
        rglru=RGLRUConfig(lru_width=4096, conv_width=4,
                          block_pattern=("recurrent", "recurrent", "attention")),
        tie_embeddings=True, emb_scale=math.sqrt(4096.0), logit_softcap=30.0,
        attn_impl="flash",
        norm="rmsnorm", act="geglu", ce_chunk=512, max_seq=524288,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, d_ff=128,
        vocab=256, window=16,
        rglru=RGLRUConfig(lru_width=64, conv_width=4,
                          block_pattern=("recurrent", "recurrent", "attention")),
        emb_scale=math.sqrt(64.0),
        param_dtype="float32", compute_dtype="float32", remat=False,
        ce_chunk=0, max_seq=64)
