"""mamba2-780m — SSD (state-space duality), arXiv:2405.21060 [unverified].

48L d_model=1536 attn-free, ssm_state=128, vocab=50280.  Sub-quadratic:
runs the long_500k shape (O(1)-state decode).
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="mamba2-780m", family="ssm",
        source="arXiv:2405.21060; unverified",
        num_layers=48, d_model=1536, vocab=50280,
        num_heads=0, num_kv_heads=0, d_ff=0,
        ssm=SSMConfig(state=128, headdim=64, ngroups=1, expand=2,
                      conv_width=4, chunk=256),
        tie_embeddings=True, norm="rmsnorm",
        ce_chunk=512, max_seq=2048,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, vocab=256,
        ssm=SSMConfig(state=16, headdim=16, ngroups=1, expand=2,
                      conv_width=4, chunk=8),
        param_dtype="float32", compute_dtype="float32", remat=False,
        ce_chunk=0, max_seq=64)
