"""opt125m-proxy — the paper's own OPT-125M family (Zhang et al. 2022).

Used by the reproduction benchmarks (Tables 1/4/6 analogs, Figures 3/4):
a 12L d_model=768 LayerNorm+GELU decoder.  ``tiny_config`` is the
train-in-repo variant (~1-10M params) used for end-to-end validation:
train on the synthetic corpus, prune with every method, compare ppl.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="opt125m-proxy", family="dense",
        source="arXiv:2205.01068 (OPT); paper's Table 1 family",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab=50272, qkv_bias=True,
        norm="layernorm", act="gelu", ce_chunk=0, max_seq=2048,
    )


def tiny_config() -> ModelConfig:
    """Trainable-on-CPU member of the same family (for e2e validation)."""
    return config().replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab=512, param_dtype="float32", compute_dtype="float32",
        remat=False, max_seq=128)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab=256, param_dtype="float32", compute_dtype="float32",
        remat=False, max_seq=64)
