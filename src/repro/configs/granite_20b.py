"""granite-20b — code model, arXiv:2405.04324 [hf].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.  The code
variant uses LayerNorm + plain GELU MLP (fc1/fc2) and multi-query
attention; positions here are rotary (assignment labels it llama-arch).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="granite-20b", family="dense",
        source="arXiv:2405.04324; hf",
        num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
        d_ff=24576, vocab=49152,
        attn_impl="flash",
        norm="layernorm", act="gelu", ce_chunk=512, max_seq=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=1, d_ff=128,
        vocab=256, param_dtype="float32", compute_dtype="float32",
        remat=False, ce_chunk=0, max_seq=64)
