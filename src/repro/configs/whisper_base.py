"""whisper-base — enc-dec with conv frontend stub, arXiv:2212.04356 [unverified].

6L encoder + 6L decoder, d_model=512 8H d_ff=2048 vocab=51865.  The mel/
conv frontend is a stub: inputs are precomputed frame embeddings
(B, 1500, 512).  Decoder context is 448 tokens (Whisper's cap) — decode
shapes clamp seq_len to max_seq.  Encoder is bidirectional; decode
shapes exercise the decoder serve_step only.
"""
from repro.configs.base import EncDecConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="whisper-base", family="encdec",
        source="arXiv:2212.04356; unverified",
        num_layers=12,  # 6 enc + 6 dec (see encdec)
        d_model=512, num_heads=8, num_kv_heads=8, d_ff=2048, vocab=51865,
        encdec=EncDecConfig(enc_layers=6, dec_layers=6, enc_seq=1500),
        norm="layernorm", act="gelu", partial_rotary=0.0,
        tie_embeddings=True, ce_chunk=0, max_seq=448,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab=256, encdec=EncDecConfig(enc_layers=2, dec_layers=2, enc_seq=16),
        param_dtype="float32", compute_dtype="float32", remat=False,
        max_seq=64)
