"""internlm2-20b — GQA, arXiv:2403.17297 [hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="internlm2-20b", family="dense",
        source="arXiv:2403.17297; hf",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab=92544, rope_theta=1_000_000.0,
        attn_impl="flash",
        norm="rmsnorm", act="silu", ce_chunk=512, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, d_ff=128,
        vocab=256, param_dtype="float32", compute_dtype="float32",
        remat=False, ce_chunk=0, max_seq=64)
