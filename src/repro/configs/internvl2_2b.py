"""internvl2-2b — InternViT + InternLM2 backbone, arXiv:2404.16821 [hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The ViT frontend
is a stub: ``input_specs`` delivers precomputed patch embeddings
(B, 256, d_model) prepended to the token stream.
"""
from repro.configs.base import ModelConfig, VLMConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="internvl2-2b", family="vlm",
        source="arXiv:2404.16821; hf",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        d_ff=8192, vocab=92553, rope_theta=1_000_000.0,
        vlm=VLMConfig(num_patches=256, patch_dim=2048),
        attn_impl="flash",
        norm="rmsnorm", act="silu", ce_chunk=512, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab=256, vlm=VLMConfig(num_patches=8, patch_dim=64),
        param_dtype="float32", compute_dtype="float32", remat=False,
        ce_chunk=0, max_seq=64)
