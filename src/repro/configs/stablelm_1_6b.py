"""stablelm-1.6b — hf:stabilityai/stablelm-2-1_6b [unverified].

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.  LayerNorm,
partial rotary 25%, qkv biases (stablelm-2 flavor).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-1.6b", family="dense",
        source="hf:stabilityai/stablelm-2-1_6b; unverified",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=5632, vocab=100352,
        attn_impl="flash",
        norm="layernorm", act="silu", partial_rotary=0.25, qkv_bias=True,
        ce_chunk=512, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab=256, param_dtype="float32", compute_dtype="float32",
        remat=False, ce_chunk=0, max_seq=64)
