"""mixtral-8x7b — 8 experts top-2 + SWA, arXiv:2401.04088 [hf].

32L d_model=4096 32H (GQA kv=8) expert_ff=14336 vocab=32000; sliding
window 4096 => KV cache capped at the window, so long_500k decode is
bounded and this arch runs the long-context shape.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch="mixtral-8x7b", family="moe",
        source="arXiv:2401.04088; hf",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab=32000, window=4096, rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=8, top_k=2, expert_ff=14336,
                      num_shared=0, shared_ff=0, norm_topk=True),
        attn_impl="flash",
        norm="rmsnorm", act="silu", ce_chunk=512, max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=64,
        vocab=256, window=16,
        moe=MoEConfig(num_experts=4, top_k=2, expert_ff=64,
                      num_shared=0, shared_ff=0, norm_topk=True),
        param_dtype="float32", compute_dtype="float32", remat=False,
        ce_chunk=0, max_seq=64)
