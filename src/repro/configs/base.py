"""Config system: one dataclass family covering all assigned architectures.

Every architecture file in ``repro/configs/`` exports ``config()`` returning a
fully-populated :class:`ModelConfig`, plus ``smoke_config()`` returning a
reduced same-family config for CPU tests.  Input shapes for the dry-run grid
are defined here (``SHAPES``) together with per-arch applicability rules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    top_k: int = 0
    expert_ff: int = 0            # per-expert FFN hidden size
    num_shared: int = 0           # always-on shared experts
    shared_ff: int = 0            # shared-expert FFN hidden size
    norm_topk: bool = True        # renormalize top-k router probs
    router_aux_coef: float = 0.01  # load-balancing aux loss


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""
    state: int = 128              # N, per-head state size
    headdim: int = 64             # P
    ngroups: int = 1              # B/C groups
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent-block parameters."""
    lru_width: int = 0            # defaults to d_model when 0
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 0
    dec_layers: int = 0
    enc_seq: int = 1500           # whisper: fixed #frames after conv stub


@dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 256        # patch embeddings prepended by the stub
    patch_dim: int = 0            # embedding dim delivered by the stub (=d_model)


@dataclass(frozen=True)
class ModelConfig:
    # identity
    arch: str = ""
    family: str = "dense"         # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""              # provenance note ([arXiv/hf]; verified tier)

    # transformer core
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0             # 0 -> d_model // num_heads

    # attention flavor
    window: Optional[int] = None          # sliding-window size (None = full)
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0           # fraction of head_dim rotated
    qkv_bias: bool = False
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    act: str = "silu"                     # silu(SwiGLU) | gelu (plain MLP)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False
    # muP-style scaling knobs (MiniCPM): h0 *= emb_scale; residual branches
    # *= residual_scale; logits *= logit_scale.
    emb_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0

    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True      # False => unroll layers (accurate HLO cost
                                  # accounting in the dry-run; bigger graphs)
    attn_impl: str = "xla"        # "xla" (unfused reference) | "flash"
                                  # (Pallas online-softmax kernel, §Perf it. 3)
    ce_chunk: int = 0             # 0 = unchunked cross-entropy; else seq-chunk size
    max_seq: int = 4096

    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (used for MODEL_FLOPS = 6 N D) -------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; ``active_only`` counts MoE active params."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.num_layers
        hd = self.resolved_head_dim()
        nq, nkv = self.num_heads, max(self.num_kv_heads, 1)
        attn = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
        if self.family == "ssm" and self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.headdim
            in_proj = d * (2 * d_in + 2 * s.ngroups * s.state + nheads)
            out_proj = d_in * d
            per_layer = in_proj + out_proj + d  # + norm
            return L * per_layer + 2 * v * d if not self.tie_embeddings else L * per_layer + v * d
        if self.family == "hybrid" and self.rglru is not None:
            r = self.rglru
            w = r.lru_width or d
            n_mat = 3 if self.act in ("silu", "geglu") else 2
            mlp_p = n_mat * d * f
            rec_layer = 2 * d * w + 2 * w * w + w * d + mlp_p + 2 * d
            att_layer = attn + mlp_p + 2 * d
            n_att = sum(1 for i in range(L)
                        if r.block_pattern[i % len(r.block_pattern)] == "attention")
            emb = v * d * (1 if self.tie_embeddings else 2)
            return (L - n_att) * rec_layer + n_att * att_layer + emb
        if self.moe is not None:
            m = self.moe
            routed = m.num_experts * 3 * d * m.expert_ff
            active_routed = m.top_k * 3 * d * m.expert_ff
            shared = m.num_shared * 3 * d * m.shared_ff if m.num_shared else 0
            # qwen-style single fused shared expert
            if m.num_shared and m.shared_ff:
                shared = 3 * d * m.shared_ff
            ffn = routed + shared + d * m.num_experts
            ffn_active = active_routed + shared + d * m.num_experts
        else:
            n_mat = 3 if self.act == "silu" else 2
            ffn = n_mat * d * f
            ffn_active = ffn
        per_layer = attn + (ffn_active if active_only else ffn) + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "encdec" and self.encdec is not None:
            e = self.encdec
            enc_layer = attn + (2 * d * f) + 2 * d
            dec_layer = attn * 2 + (2 * d * f) + 3 * d  # self+cross attn
            return e.enc_layers * enc_layer + e.dec_layers * dec_layer + emb
        return L * per_layer + emb


# ---------------------------------------------------------------------------
# Dry-run input-shape grid (assigned): name -> (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs with sub-quadratic decode paths run long_500k (see DESIGN.md).
SUBQUADRATIC_ARCHS = {"mamba2-780m", "recurrentgemma-9b", "mixtral-8x7b"}

ALL_ARCHS: List[str] = [
    "mamba2-780m",
    "internvl2-2b",
    "minicpm-2b",
    "stablelm-1.6b",
    "internlm2-20b",
    "granite-20b",
    "recurrentgemma-9b",
    "whisper-base",
    "qwen2-moe-a2.7b",
    "mixtral-8x7b",
]


def shape_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for one dry-run cell."""
    if shape == "long_500k" and arch not in SUBQUADRATIC_ARCHS:
        return False, "pure full-attention arch: 500k-token KV decode is unbounded; skipped per spec"
    return True, ""


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honoring applicability."""
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            ok, why = shape_applicable(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok, why
