"""Training loop: jitted step, grad accumulation, checkpoint/restart.

Fault tolerance: every ``ckpt_every`` steps the full training state
(params, optimizer moments, data cursor, step) is written atomically via
``repro.checkpoint.store``; ``Trainer.restore`` resumes bit-exact from
the latest complete checkpoint (the data stream is a pure function of
the cursor, so the replayed batch sequence is identical — covered by
tests/test_train.py::test_resume_bit_exact).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.data.corpus import MarkovCorpus, batch_to_model_inputs
from repro.models.registry import ModelDef
from repro.train import optim
from repro.utils import get_logger

log = get_logger("trainer")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    batch: int = 8
    seq: int = 64
    grad_accum: int = 1
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 2
    eval_every: int = 50
    eval_batches: int = 4
    log_every: int = 10
    seed: int = 0
    optim: optim.AdamWConfig = optim.AdamWConfig()
    # recorded in every checkpoint's manifest extra (e.g. arch/smoke/
    # corpus_seed) so downstream consumers — launch/evaluate.py — can
    # rebuild the exact model/corpus from the run dir alone
    ckpt_extra: Optional[Dict[str, Any]] = None


def make_train_step(model: ModelDef, ocfg: optim.AdamWConfig):
    """Returns jitted (params, opt_state, batch) -> (params, opt_state, metrics).

    With grad accumulation the caller streams micro-batches through
    ``accum_step`` and applies ``apply_step`` once per global batch.
    """

    def loss_fn(params, batch):
        l, metrics = model.loss(params, batch)
        return l, metrics

    @jax.jit
    def train_step(params, opt_state, batch):
        (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = optim.update(ocfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **om, "loss": l}

    @jax.jit
    def grad_step(params, batch):
        (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return grads, l

    @jax.jit
    def apply_grads(params, opt_state, grads):
        params, opt_state, om = optim.update(ocfg, grads, opt_state, params)
        return params, opt_state, om

    return train_step, grad_step, apply_grads


def evaluate_ppl(model: ModelDef, params, corpus: MarkovCorpus, batch: int,
                 seq: int, n_batches: int, extras: Optional[Dict] = None) -> float:
    """Held-out perplexity (teacher-forced CE on the valid split)."""
    tot, cnt = 0.0, 0
    it = corpus.batches(batch, seq, split="valid")
    # reuse the eval subsystem's weak-keyed per-model CE closure: a fresh
    # @jax.jit here would re-trace on every evaluate_ppl call (JAX004 /
    # the PR 6 executable-accumulation class)
    from repro.eval.perplexity import _ce_fn
    ce = _ce_fn(model)

    for _ in range(n_batches):
        _, toks = next(it)
        b = {k: jnp.asarray(v) for k, v in batch_to_model_inputs(toks).items()}
        if extras:
            b.update({k: jnp.asarray(v[:toks.shape[0]]) for k, v in extras.items()})
        tot += float(ce(params, b))
        cnt += 1
    return float(np.exp(tot / max(cnt, 1)))


class Trainer:
    def __init__(self, model: ModelDef, corpus: MarkovCorpus, cfg: TrainConfig,
                 extras_fn: Optional[Callable[[int], Dict]] = None):
        self.model, self.corpus, self.cfg = model, corpus, cfg
        self.extras_fn = extras_fn
        self.train_step, self.grad_step, self.apply_grads = make_train_step(
            model, cfg.optim)
        self.params = model.init(jax.random.PRNGKey(cfg.seed))
        self.opt_state = optim.init(self.params)
        self.step = 0
        self.history: list = []

    # -- checkpointing -----------------------------------------------------
    def save(self) -> Optional[str]:
        if not self.cfg.ckpt_dir:
            return None
        state = {"params": self.params, "mu": self.opt_state.mu,
                 "nu": self.opt_state.nu,
                 "opt_step": self.opt_state.step}
        path = store.save(self.cfg.ckpt_dir, store.step_name(self.step), state,
                          extra={"step": self.step, "time": time.time(),
                                 **(self.cfg.ckpt_extra or {})})
        store.prune_old(self.cfg.ckpt_dir, keep=self.cfg.keep_ckpts)
        return path

    def restore(self) -> bool:
        if not self.cfg.ckpt_dir:
            return False
        latest = store.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        like = {"params": self.params, "mu": self.opt_state.mu,
                "nu": self.opt_state.nu, "opt_step": self.opt_state.step}
        state, extra = store.load(self.cfg.ckpt_dir, store.step_name(latest), like)
        self.params = state["params"]
        self.opt_state = optim.AdamWState(step=state["opt_step"], mu=state["mu"],
                                          nu=state["nu"])
        self.step = int(extra["step"])
        log.info("restored checkpoint at step %d", self.step)
        return True

    # -- loop ----------------------------------------------------------------
    def _batch_at(self, it) -> Dict[str, jnp.ndarray]:
        _, toks = next(it)
        b = {k: jnp.asarray(v) for k, v in batch_to_model_inputs(toks).items()}
        if self.extras_fn is not None:
            b.update(self.extras_fn(toks.shape[0]))
        return b

    def run(self) -> Dict[str, Any]:
        cfg = self.cfg
        it = self.corpus.batches(cfg.batch, cfg.seq, split="train",
                                 start_step=self.step * max(cfg.grad_accum, 1))
        t0 = time.perf_counter()
        while self.step < cfg.steps:
            if cfg.grad_accum <= 1:
                batch = self._batch_at(it)
                self.params, self.opt_state, m = self.train_step(
                    self.params, self.opt_state, batch)
            else:
                grads = None
                loss_sum = 0.0
                for _ in range(cfg.grad_accum):
                    g, l = self.grad_step(self.params, self._batch_at(it))
                    loss_sum += float(l)
                    grads = g if grads is None else jax.tree_util.tree_map(
                        jnp.add, grads, g)
                grads = jax.tree_util.tree_map(lambda x: x / cfg.grad_accum, grads)
                self.params, self.opt_state, m = self.apply_grads(
                    self.params, self.opt_state, grads)
                m = {**m, "loss": jnp.float32(loss_sum / cfg.grad_accum)}
            self.step += 1
            if self.step % cfg.log_every == 0 or self.step == cfg.steps:
                rec = {k: float(v) for k, v in m.items()}
                rec["step"] = self.step
                self.history.append(rec)
                log.info("step %d loss %.4f lr %.2e", self.step, rec["loss"],
                         rec.get("lr", 0.0))
            if cfg.ckpt_dir and (self.step % cfg.ckpt_every == 0
                                 or self.step == cfg.steps):
                self.save()
        wall = time.perf_counter() - t0
        return {"steps": self.step, "wall_seconds": wall, "history": self.history,
                "final_loss": self.history[-1]["loss"] if self.history else None}
