"""Training substrate: AdamW + schedules, trainer with checkpoint/restart."""
from repro.train.optim import AdamWConfig, AdamWState
from repro.train.trainer import TrainConfig, Trainer, evaluate_ppl

__all__ = ["AdamWConfig", "AdamWState", "TrainConfig", "Trainer", "evaluate_ppl"]
