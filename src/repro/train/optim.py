"""Optimizers and LR schedules (no external deps): AdamW, cosine & WSD.

WSD (warmup-stable-decay) is the MiniCPM schedule — included because
minicpm-2b is an assigned arch.  All state is a pytree; the update is a
pure function usable inside jit/pjit (the DP mesh shards it like params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 1000
    decay_frac: float = 0.1         # WSD: final fraction spent decaying
    min_lr_frac: float = 0.1


def schedule_fn(cfg: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "const":
            return cfg.lr * warm
        total = jnp.float32(cfg.total_steps)
        if cfg.schedule == "cosine":
            t = jnp.clip((step - cfg.warmup_steps) /
                         jnp.maximum(total - cfg.warmup_steps, 1), 0.0, 1.0)
            cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
            return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)
        # WSD: stable at lr, then linear decay over the last decay_frac
        decay_start = total * (1.0 - cfg.decay_frac)
        t = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
        return cfg.lr * warm * (1.0 - (1.0 - cfg.min_lr_frac) * t)

    return fn


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params: Any) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
           ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step.  Params may be bf16; moments and math are fp32."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = schedule_fn(cfg)(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        upd_ = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (upd_ + wd)
        return new_p.astype(p.dtype), mu, nu

    flat = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray))
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {
        "lr": lr, "grad_norm": gnorm}
