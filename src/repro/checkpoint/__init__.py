"""Atomic, crc-verified checkpoint store."""
from repro.checkpoint.store import (CheckpointCorrupt, exists, latest_step,
                                    list_steps, load, prune_old, save, step_name)

__all__ = ["CheckpointCorrupt", "exists", "latest_step", "list_steps", "load",
           "prune_old", "save", "step_name"]
