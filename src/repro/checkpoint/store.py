"""Atomic, integrity-checked checkpoint store (fault-tolerance substrate).

Layout per checkpoint:

    <dir>/step_000123/
        arrays.npz          flattened pytree ("/"-joined paths -> arrays)
        MANIFEST.json       {step, keys, crc32 per key, extra, complete: true}

Writes go to ``<dir>/.tmp.<name>`` then ``os.replace`` onto the final
path — a crashed writer leaves no half-visible checkpoint, and a reader
only trusts directories whose manifest says ``complete``.  CRC32 of every
array is verified on load; corruption => CheckpointCorrupt (the restart
logic falls back to the previous step).

Used by the trainer (params+opt+data cursor), the pruning scheduler
(per-unit results) and the serving weight store.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import get_logger
from repro.utils.tree import flatten_with_paths, set_path

log = get_logger("checkpoint")


class CheckpointCorrupt(RuntimeError):
    pass


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


# npz can't hold ml_dtypes (bfloat16 etc.) — store them as same-width uint
# views and restore from the manifest's recorded dtype.
_WIDE_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
              "float8_e5m2": np.uint8}


def _to_storable(a: np.ndarray) -> np.ndarray:
    name = str(a.dtype)
    if name in _WIDE_VIEW:
        return np.ascontiguousarray(a).view(_WIDE_VIEW[name])
    return a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _WIDE_VIEW:
        import ml_dtypes
        return a.view(getattr(ml_dtypes, dtype_name))
    return a


def save(directory: str, name: str, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomically write ``tree`` under <directory>/<name>; returns the path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, name)
    tmp = os.path.join(directory, f".tmp.{name}.{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = flatten_with_paths(tree)
    arrays = {p: np.asarray(x) for p, x in flat}
    storable = {p: _to_storable(a) for p, a in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **storable)
    manifest = {
        "keys": sorted(arrays.keys()),
        "crc32": {p: _crc(a) for p, a in storable.items()},
        "dtypes": {p: str(a.dtype) for p, a in arrays.items()},
        "extra": extra or {},
        "complete": True,
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def exists(directory: str, name: str) -> bool:
    m = os.path.join(directory, name, "MANIFEST.json")
    if not os.path.exists(m):
        return False
    try:
        with open(m) as f:
            return bool(json.load(f).get("complete"))
    except (json.JSONDecodeError, OSError):
        return False


def load(directory: str, name: str, like: Optional[Any] = None,
         verify: bool = True) -> Tuple[Any, Dict]:
    """Load (tree, extra).  ``like`` rebuilds the nested structure (and
    device dtypes); without it a flat {path: np.array} dict is returned."""
    base = os.path.join(directory, name)
    with open(os.path.join(base, "MANIFEST.json")) as f:
        manifest = json.load(f)
    if not manifest.get("complete"):
        raise CheckpointCorrupt(f"{base}: incomplete manifest")
    data = np.load(os.path.join(base, "arrays.npz"))
    out: Dict[str, np.ndarray] = {}
    for key in manifest["keys"]:
        a = data[key]
        if verify and _crc(a) != manifest["crc32"][key]:
            raise CheckpointCorrupt(f"{base}: crc mismatch for {key}")
        out[key] = _from_storable(a, manifest["dtypes"][key])
    if like is None:
        return out, manifest["extra"]
    tree = like
    for p, ref in flatten_with_paths(like):
        if p not in out:
            raise CheckpointCorrupt(f"{base}: missing key {p}")
        tree = set_path(tree, p, jnp.asarray(out[p], dtype=ref.dtype))
    return tree, manifest["extra"]


def list_steps(directory: str, prefix: str = "step_") -> List[int]:
    """Completed checkpoint steps, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith(prefix) and exists(directory, d):
            try:
                steps.append(int(d[len(prefix):]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str, prefix: str = "step_") -> Optional[int]:
    steps = list_steps(directory, prefix)
    return steps[-1] if steps else None


def step_name(step: int) -> str:
    return f"step_{step:08d}"


def prune_old(directory: str, keep: int = 3, prefix: str = "step_") -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    steps = list_steps(directory, prefix)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"{prefix}{s:08d}"), ignore_errors=True)
