"""Mesh-native execution: ONE sharded substrate for prune -> eval -> serve.

Before this module, the mesh machinery lived only in ``distributed/``
(training) while the three user-facing pipelines each ran single-device:
calibration forwards on one chip, perplexity batches in a host loop,
every decode step on one device.  :class:`MeshExecutor` is the single
owner of mesh construction and placement that all three now share
(DESIGN.md §10):

* **prune** — Gram accumulation goes data-parallel over calibration
  micro-batches (per-shard Gram scan + one ``psum``, the pipeline's only
  collective), and FISTA group solves optionally row-shard over "model"
  through the existing ``distributed/rowfista`` path;
* **eval**  — perplexity / KL batches shard over "data": each device
  evaluates whole batches locally, per-batch scalars come back in batch
  order so the host-side reduction is bitwise-identical to the serial
  loop;
* **serve** — params place onto the mesh via the Megatron rules in
  ``distributed/sharding.py`` (column/row per block -> one all-reduce
  per block in decode) and the paged KV pool gains a heads-sharded
  device layout; GSPMD partitions the jitted decode step.

Determinism contract: XLA's CPU all-reduce is an ordered linear
reduction over the axis, so with one micro-batch per data shard the
psum-merged Gram statistics are **bitwise-equal** to the serial
left-fold (pinned in tests/distributed_cases.py).  With several batches
per shard the merge reassociates the fp32 sum and parity is ulp-level.

Everything here degrades gracefully: a :class:`MeshConfig` of 1x1 (or a
dimension that does not divide the workload) falls back to the exact
single-device code path, so the executor can be threaded unconditionally.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.distributed import rowfista, sharding
from repro.utils import get_logger

log = get_logger("executor")


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """The strict ``mesh`` section of a ``PruneRecipe`` (and the value a
    launcher's ``--mesh dxm`` flag parses into).

    ``devices`` is the total device count the run expects (0 = all
    visible); ``data_parallel`` x ``model_parallel`` must factor it
    (``data_parallel`` 0 = derive from the other two).  A 1x1 config is
    the explicit "single device" request and builds no mesh.
    """

    devices: int = 0
    data_parallel: int = 0
    model_parallel: int = 1

    def __post_init__(self) -> None:
        for name in ("devices", "data_parallel", "model_parallel"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 0:
                raise ValueError(f"mesh.{name} must be an int >= 0, got {v!r}")
        if self.model_parallel == 0:
            raise ValueError("mesh.model_parallel must be >= 1")

    @classmethod
    def parse(cls, spec: Any) -> "MeshConfig":
        """``"4x2"`` / ``"8"`` / ``{"devices": ...}`` / MeshConfig -> MeshConfig.

        The string form is ``DATAxMODEL`` (the launchers' ``--mesh`` flag);
        a bare integer means that many data shards with no model axis.
        """
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, dict):
            return cls(**spec)
        text = str(spec).strip().lower()
        parts = text.split("x")
        try:
            if len(parts) == 1:
                d = int(parts[0])
                return cls(devices=d, data_parallel=d, model_parallel=1)
            if len(parts) == 2:
                d, m = int(parts[0]), int(parts[1])
                return cls(devices=d * m, data_parallel=d, model_parallel=m)
        except ValueError:
            pass
        raise ValueError(f"bad mesh spec {spec!r}; expected 'DATAxMODEL' "
                         f"(e.g. '4x2') or a device count")

    def resolve(self, available: Optional[int] = None) -> Tuple[int, int]:
        """(data, model) sizes against ``available`` devices; validates
        that the factorization matches the device count."""
        avail = jax.device_count() if available is None else available
        total = self.devices or (self.data_parallel * self.model_parallel
                                 if self.data_parallel else avail)
        data = self.data_parallel or max(total // self.model_parallel, 1)
        model = self.model_parallel
        if data * model != total:
            raise ValueError(
                f"mesh {data}x{model} does not factor devices={total}")
        if total > avail:
            raise ValueError(
                f"mesh {data}x{model} needs {total} devices, only "
                f"{avail} visible (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={total} on CPU)")
        return data, model

    @property
    def is_single(self) -> bool:
        return (self.model_parallel == 1 and self.data_parallel in (0, 1)
                and self.devices in (0, 1))

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class MeshExecutor:
    """Owns one (data, model) mesh and every placement decision the
    three pipelines make against it.

    Built once per run (``api.prune`` / ``launch`` CLIs) and passed by
    object — it never serializes; the :class:`MeshConfig` it came from
    does.
    """

    def __init__(self, cfg: MeshConfig = MeshConfig(),
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        if mesh is not None:
            self.mesh = mesh
        else:
            data, model = cfg.resolve()
            self.mesh = jax.make_mesh((data, model), ("data", "model"))
        self.data_size = int(self.mesh.shape["data"])
        self.model_size = int(self.mesh.shape["model"])
        # jitted shard_map closures, keyed by call site: a fresh closure
        # per call would re-trace and re-compile the identical sharded
        # program every time (eval scores dense + pruned + KL per report;
        # the Gram scan runs per group x bucket x unit)
        self._jit_cache: Dict[Any, Callable] = {}

    def _cached(self, key: Any, build: Callable[[], Callable]) -> Callable:
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = build()
            self._jit_cache[key] = fn
        return fn

    @classmethod
    def from_spec(cls, spec: Any) -> Optional["MeshExecutor"]:
        """Parse a ``--mesh`` flag value; None/empty/1x1 -> no executor."""
        if spec in (None, "", "1", "1x1"):
            return None
        cfg = MeshConfig.parse(spec)
        return None if cfg.is_single else cls(cfg)

    def describe(self) -> Dict[str, Any]:
        return {"data": self.data_size, "model": self.model_size,
                "devices": self.data_size * self.model_size}

    # ------------------------------------------------------------------
    # placement (GSPMD: NamedSharding via the Megatron rules)
    # ------------------------------------------------------------------
    def shard_params(self, params: Any) -> Any:
        """Place a param tree on the mesh per ``distributed/sharding.py``
        (column/row tensor parallelism over "model"; non-divisible dims
        and rule-less leaves — biases, norms, packed-2:4 stores —
        replicate via ``_fit_spec``)."""
        specs = sharding.param_specs(params)
        shardings = sharding.make_shardings(self.mesh, specs, params)
        return jax.device_put(params, shardings)

    def replicate(self, tree: Any) -> Any:
        return jax.device_put(
            tree, jax.tree_util.tree_map(
                lambda _: NamedSharding(self.mesh, P()), tree))

    def shard_paged_pool(self, pool: Any) -> Any:
        """Heads-sharded device layout of the paged KV pool: the
        (L, num_blocks*block_size, nkv, hd) tensors shard ``nkv`` over
        "model" (each model shard holds its attention heads' pages —
        the decode gather/scatter is then fully local per shard and the
        one all-reduce per block lands after wo).  Falls back to
        replication when nkv does not divide the axis (MQA)."""

        def spec(leaf):
            if getattr(leaf, "ndim", 0) == 4:
                return sharding._fit_spec(self.mesh,
                                          P(None, None, "model", None),
                                          leaf.shape)
            return P()

        return jax.device_put(
            pool, jax.tree_util.tree_map(
                lambda l: NamedSharding(self.mesh, spec(l)), pool))

    def replicate_logits(self, logits: jnp.ndarray) -> jnp.ndarray:
        """Constrain sampling inputs to full replication.

        GSPMD happily leaves decode logits vocab-sharded (tied embeddings
        shard the vocab dim), but ``jax.random.categorical`` over a
        sharded operand draws DIFFERENT tokens than over the same values
        replicated — the partitioned RNG lowering is not value-identical.
        Every serving surface routes its logits through this constraint
        before sampling, which is what makes temperature-sampled TP
        decode token-identical to the single-device path.  Works both
        inside jit (``with_sharding_constraint``) and eagerly.
        """
        sh = NamedSharding(self.mesh, P())
        if isinstance(logits, jax.core.Tracer):
            return jax.lax.with_sharding_constraint(logits, sh)
        return jax.device_put(logits, sh)

    def shard_serve_state(self, state: Any) -> Any:
        """Contiguous serving caches (L, B, S, nkv, hd): shard heads over
        "model" (replicate everything non-5D / non-divisible)."""

        def spec(leaf):
            if getattr(leaf, "ndim", 0) == 5:
                return sharding._fit_spec(
                    self.mesh, P(None, None, None, "model", None), leaf.shape)
            return P()

        return jax.device_put(
            state, jax.tree_util.tree_map(
                lambda l: NamedSharding(self.mesh, spec(l)), state))

    # ------------------------------------------------------------------
    # prune: data-parallel Gram accumulation (one psum per group)
    # ------------------------------------------------------------------
    def can_shard_batches(self, num_batches: int) -> bool:
        return self.data_size > 1 and num_batches % self.data_size == 0

    def sharded_group_stats(self, scan_fn: Callable, init: Dict[str, Any],
                            current: Any, ws: Dict[str, jnp.ndarray],
                            dense_caps: Any, pruned_states: Any,
                            **static_kw: Any) -> Dict[str, Any]:
        """Data-parallel run of ``core.sequential._group_stats_scan``:
        every device scans ITS slice of the stacked calibration
        micro-batches from zero statistics, one ``psum`` over "data"
        merges, and the carried-in ``init`` is added on top.

        With one micro-batch per shard the psum's ordered reduction
        makes the result bitwise-equal to the serial scan (see module
        docstring); otherwise equal to fp32 round-off.  The carried-in
        ``init`` (nonzero when a group spans several shape buckets)
        seeds SHARD 0's scan rather than being added after the merge, so
        the association order matches the serial left-fold
        ``((init + g0) + g1) + ...`` exactly.
        """
        zeros = jax.tree_util.tree_map(jnp.zeros_like, init)

        def build():
            def local(ini, z, cur, w, caps, ps):
                first = jax.lax.axis_index("data") == 0
                start = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(first, a, b), ini, z)
                stats = scan_fn(start, cur, w, caps, ps, **static_kw)
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.psum(x, "data"), stats)

            # prefix specs (structure-independent, so the jitted closure
            # is reusable across shape buckets of the same group)
            return jax.jit(shard_map(
                local, mesh=self.mesh,
                in_specs=(P(), P(), P(), P(), P("data"), P("data")),
                out_specs=P(),
                check_rep=False))  # psum outputs are replicated; jit-
            # inside-shard_map scans carry no rep annotations on 0.4.x

        fn = self._cached(
            ("gram", scan_fn,
             tuple(sorted(static_kw.items(), key=lambda kv: kv[0]))), build)
        # span covers the sharded dispatch only (recording stays outside
        # the jitted body — OBS001); async dispatch returns immediately,
        # so `dur` measures launch overhead, not device seconds
        with obs.span("mesh.group_stats", data=self.data_size,
                      model=self.model_size):
            return fn(init, zeros, current, ws, dense_caps, pruned_states)

    # ------------------------------------------------------------------
    # prune: row-sharded FISTA solves over "model" (rowfista path)
    # ------------------------------------------------------------------
    def can_row_shard(self, rows: int) -> bool:
        return self.model_size > 1 and rows % self.model_size == 0

    def row_fista_solve(self, G: jnp.ndarray, B: jnp.ndarray, y0: jnp.ndarray,
                        lam, *, L, max_iters: int, tol: float,
                        momentum: str = "fista", step_impl: str = "jnp"
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """One FISTA solve with the m rows of (B, y0) sharded over
        "model" and G replicated — zero collectives per iteration
        (``distributed/rowfista.py``).  Same call/return contract as
        ``core.fista.solve`` so it drops into the Algorithm-1 host loop
        as its ``inner_solve`` (iteration count reported as the bound —
        per-shard early stopping is local)."""
        y = rowfista.sharded_solve(self.mesh, G, B, y0, lam, L,
                                   max_iters=max_iters, tol=tol,
                                   momentum=momentum, step_impl=step_impl)
        return y, jnp.int32(max_iters)

    # ------------------------------------------------------------------
    # eval: batch-sharded map over "data"
    # ------------------------------------------------------------------
    def data_map(self, fn: Callable[..., Any], stacked: Any,
                 *params: Any, cache_key: Any = None) -> Any:
        """Evaluate ``fn(batch, *params) -> pytree of scalars`` for every
        batch of a leading-axis-stacked batch tree, batches sharded over
        "data" and every ``params`` tree replicated.

        Each device evaluates WHOLE batches locally, so every per-batch
        value is the same fp32 number the serial loop produces; outputs
        come back stacked on the leading axis in batch order.  The
        caller's host-side reduction therefore matches the unsharded
        path bitwise.

        ``cache_key`` (e.g. ``(model, "ce")``) reuses the jitted sharded
        program across calls — callers passing a fresh ``fn`` lambda per
        call MUST pass a key describing its semantics, or every report
        re-traces (the sharded analog of the serial paths' per-model jit
        caches).
        """

        def build():
            def local(st, *ps):
                def body(_, b):
                    return None, fn(b, *ps)

                _, ys = jax.lax.scan(body, None, st)
                return ys

            return jax.jit(shard_map(
                local, mesh=self.mesh,
                in_specs=(P("data"),) + (P(),) * len(params),
                out_specs=P("data"),
                check_rep=False))

        mapped = build() if cache_key is None else \
            self._cached(("map", cache_key, len(params)), build)
        with obs.span("mesh.data_map", data=self.data_size,
                      key=str(cache_key)):
            return mapped(stacked, *params)
