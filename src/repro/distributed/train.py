"""Sharded training/serving step builders (jit + NamedSharding).

GSPMD does the heavy lifting: given the param/batch PartitionSpecs from
distributed/sharding.py, ``jax.jit(..., in_shardings, out_shardings)``
lowers one SPMD program per mesh with all collectives inserted (DP grad
all-reduce as reduce-scatter+all-gather where profitable, TP block
all-reduces, MoE all-to-alls).  These builders are shared by the real
trainer and the multi-pod dry-run — the dry-run just stops after
``.lower().compile()``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shard_rules
from repro.models.registry import ModelDef
from repro.train import optim


def dp_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_train_step(model: ModelDef, mesh: Mesh,
                    ocfg: optim.AdamWConfig = optim.AdamWConfig(),
                    donate: bool = True):
    """Returns (train_step, shardings) where train_step(params, opt, batch)
    -> (params, opt, metrics) is a fully sharded jit."""
    dp = dp_axes_of(mesh)

    def step(params, opt_state, batch):
        def loss_fn(p):
            l, m = model.loss(p, batch)
            return l, m

        (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, opt2, om = optim.update(ocfg, grads, opt_state, params)
        return params2, opt2, {**metrics, **om, "loss": l}

    def shardings_for(params, opt_state, batch):
        pspec = shard_rules.param_specs(params)
        psh = shard_rules.make_shardings(mesh, pspec, params)
        osh = optim.AdamWState(step=NamedSharding(mesh, P()),
                               mu=psh, nu=jax.tree_util.tree_map(lambda s: s, psh))
        bsh = shard_rules.make_shardings(mesh, shard_rules.batch_specs(batch, dp), batch)
        return psh, osh, bsh

    def build(params, opt_state, batch):
        psh, osh, bsh = shardings_for(params, opt_state, batch)
        msh = NamedSharding(mesh, P())
        fn = jax.jit(step,
                     in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1) if donate else ())
        return fn, (psh, osh, bsh)

    return build


def make_serve_step(model: ModelDef, mesh: Mesh):
    """Sharded one-token decode: batch over DP axes, caches batch-sharded."""
    dp = dp_axes_of(mesh)

    def step(params, state, token, pos):
        return model.serve_step(params, state, token, pos)

    def build(params, state, token):
        psh = shard_rules.make_shardings(mesh, shard_rules.param_specs(params), params)
        # layer-stacked caches are (L, B, ...); rglru keeps per-layer (B, ...)
        bidx = 0 if model.cfg.family == "hybrid" else 1
        ssh = shard_rules.make_shardings(
            mesh, shard_rules.state_specs(state, dp, batch_axis_index=bidx), state)
        tsh = NamedSharding(mesh, P(dp))
        fn = jax.jit(step, in_shardings=(psh, ssh, tsh, None),
                     out_shardings=(None, ssh))
        return fn, (psh, ssh, tsh)

    return build
