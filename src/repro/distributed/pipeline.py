"""GPipe-style pipeline parallelism over the "pod" mesh axis (shard_map).

Layer stacks are reshaped (L, ...) -> (n_stages, L/n_stages, ...) with the
stage dim sharded over ``axis``; microbatches flow stage-to-stage through
``jax.lax.ppermute`` in the classic GPipe schedule (T = M + S - 1 ticks,
bubble fraction (S-1)/T).  Everything runs under one shard_map, so the
whole pipeline is a single SPMD program — pod-to-pod traffic is exactly
one (microbatch x hidden) tensor per tick over the pod-interconnect
links, which is what the multi-pod dry-run's collective-permute entries
account for (see EXPERIMENTS.md §Dry-run).

The default multi-pod configuration treats "pod" as an outer DP axis;
pipeline mode is selected with ``--pipeline`` in the launch drivers.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.utils import compat

from repro.utils.tree import tree_map_with_path


def stack_to_stages(stacked: Any, n_stages: int) -> Any:
    """(L, ...) leaves -> (n_stages, L/n_stages, ...)."""

    def visit(path, leaf):
        L = leaf.shape[0]
        assert L % n_stages == 0, f"{path}: {L} layers not divisible by {n_stages} stages"
        return leaf.reshape((n_stages, L // n_stages) + leaf.shape[1:])

    return tree_map_with_path(visit, stacked)


def pipeline_apply(mesh: Mesh, stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, xs: jnp.ndarray, axis: str = "pod"
                   ) -> jnp.ndarray:
    """Run the pipeline.

    ``stage_params``: leaves (n_stages, L/S, ...) — sharded over ``axis``.
    ``xs``: (M, mb, ...) microbatch stack (replicated; only stage 0 reads it).
    ``stage_fn(params_one_stage, x) -> y`` applies one stage's layers.
    Returns (M, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    M = xs.shape[0]
    T = M + n_stages - 1

    def per_stage(params, xs_local):
        params = jax.tree_util.tree_map(lambda p: p[0], params)  # squeeze stage dim
        idx = jax.lax.axis_index(axis)
        # initial carries must be marked pod-varying: they mix with idx-
        # dependent values inside the loop (shard_map vma typing)
        zero = compat.pvary(jnp.zeros_like(xs_local[0]), (axis,))
        outputs = compat.pvary(jnp.zeros_like(xs_local), (axis,))

        def tick(t, state):
            carry, outputs = state
            # stage 0 injects microbatch t; other stages consume the carry
            feed = jax.lax.dynamic_index_in_dim(
                xs_local, jnp.clip(t, 0, M - 1), keepdims=False)
            x_in = jnp.where(idx == 0, feed, carry)
            y = stage_fn(params, x_in)
            # forward the activation one stage down the ring
            carry_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage emits microbatch t-(S-1)
            out_t = t - (n_stages - 1)
            upd = jax.lax.dynamic_update_index_in_dim(
                outputs, y, jnp.clip(out_t, 0, M - 1), axis=0)
            outputs = jnp.where((idx == n_stages - 1) & (out_t >= 0), upd, outputs)
            return carry_next, outputs

        _, outputs = jax.lax.fori_loop(0, T, tick, (zero, outputs))
        # broadcast the last stage's outputs to every stage
        mask = (idx == n_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, axis)

    in_specs = (tree_map_with_path(lambda p, l: P(axis), stage_params), P())
    fn = shard_map(per_stage, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=True)  # MESH001: explicit contract
    return fn(stage_params, xs)


def split_microbatches(x: jnp.ndarray, n_micro: int) -> jnp.ndarray:
    B = x.shape[0]
    assert B % n_micro == 0
    return x.reshape((n_micro, B // n_micro) + x.shape[1:])


def merge_microbatches(xs: jnp.ndarray) -> jnp.ndarray:
    return xs.reshape((xs.shape[0] * xs.shape[1],) + xs.shape[2:])
