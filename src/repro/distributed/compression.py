"""Gradient compression for DP all-reduce: int8 quantization + error feedback.

At 1000-node scale the DP gradient all-reduce is the dominant collective
for small/medium models; int8 with per-tensor scales cuts its bytes 4x.
Error feedback (Seide et al. / EF-SGD) accumulates the quantization
residual locally and re-injects it next step, which provably preserves
SGD convergence.  The low-bit all-reduce is expressed as
all_gather(int8) + local dequant-sum inside shard_map, so the wire
format really is int8 (psum of int8 would overflow).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8: returns (q int8, scale fp32)."""
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jnp.ndarray, residual: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression of one leaf.

    Returns (q, scale, new_residual): the residual carries what int8
    couldn't represent into the next step."""
    corrected = g.astype(jnp.float32) + residual
    q, scale = quantize(corrected)
    new_residual = corrected - dequantize(q, scale)
    return q, scale, new_residual


def init_residuals(grads: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_allreduce(mesh: Mesh, grads: Any, residuals: Any,
                         data_axis: str = "data") -> Tuple[Any, Any]:
    """DP mean of ``grads`` over ``data_axis`` with int8 wire format.

    Inputs are per-shard gradients (each device's local grads, batch
    sharded); output is the dequantized mean, replicated over the axis.
    Residuals are per-device state and stay sharded.
    """
    axis_size = mesh.shape[data_axis]

    def leaf_allreduce(g, r):
        def local(gl, rl):
            q, scale, new_r = ef_compress(gl[0], rl[0])
            # all_gather the int8 payload + scales (the 4x-smaller wire)
            qs = jax.lax.all_gather(q, data_axis)          # (D, ...)
            ss = jax.lax.all_gather(scale, data_axis)      # (D,)
            mean = jnp.tensordot(ss.astype(jnp.float32),
                                 qs.astype(jnp.float32), axes=1) / axis_size
            return mean[None], new_r[None]

        fn = shard_map(local, mesh=mesh,
                       in_specs=(P(data_axis), P(data_axis)),
                       out_specs=(P(data_axis), P(data_axis)),
                       check_rep=True)  # MESH001: explicit contract
        mean, new_r = fn(g, r)
        return mean, new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r, _ = jax.tree_util.tree_flatten(residuals)
    means, new_rs = [], []
    for g, r in zip(flat_g, flat_r):
        m, nr = leaf_allreduce(g, r)
        means.append(m)
        new_rs.append(nr)
    return (jax.tree_util.tree_unflatten(tdef, means),
            jax.tree_util.tree_unflatten(tdef, new_rs))
