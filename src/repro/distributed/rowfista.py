"""Row-parallel FISTA (shard_map) + distributed Gram accumulation.

The LASSO (paper Eq. 4) is row-separable: row i of W* solves an
independent problem over the SAME Gram matrix G.  So the inner FISTA
loop shards the m rows of (Y, B) over the "model" axis with G
replicated — **zero collectives per iteration** (DESIGN.md §2).  The
only communication in the whole pruning pipeline is one psum per
operator when the Gram statistics are accumulated from data-sharded
calibration activations.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import fista as fista_lib
from repro.core import gram as gram_lib
from repro.core.gram import GramStats


def sharded_solve(mesh: Mesh, G: jnp.ndarray, B: jnp.ndarray, y0: jnp.ndarray,
                  lam, L, max_iters: int = 20, tol: float = fista_lib.DEFAULT_TOL,
                  axis: str = "model", momentum: str = "fista",
                  step_impl: str = "jnp") -> jnp.ndarray:
    """FISTA with rows of B/y0 sharded over ``axis``; G replicated.

    The row count m must divide the axis size x ... (padding handled by
    the caller; operators here always have 128-multiple rows at scale).
    Stopping uses the local shard's delta — safe because the math of each
    shard is independent; max_iters bounds the divergence between shards
    (they run the same number of iterations under jit anyway since the
    while_loop is per-shard).
    """
    lam = jnp.float32(lam)
    L = jnp.float32(L)

    def local(g, b, y):
        out, _ = fista_lib.solve(g, b, y, lam, L=L, max_iters=max_iters,
                                 tol=tol, momentum=momentum,
                                 step_impl=step_impl)
        return out

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, None), P(axis, None), P(axis, None)),
                   out_specs=P(axis, None),
                   check_rep=False)  # no replication rule for while_loop
    return fn(G, B.astype(jnp.float32), y0.astype(jnp.float32))


def sharded_accumulate(mesh: Mesh, stats: GramStats, x_dense: jnp.ndarray,
                       x_pruned: jnp.ndarray, wx_dense: jnp.ndarray,
                       data_axis: str = "data") -> GramStats:
    """Gram accumulation with the token batch sharded over ``data_axis``:
    each shard computes its local outer products, then ONE psum merges.
    (This is the only collective of the pruning pipeline.)"""

    def local(G, C, H, h, cnt, xd, xp, wx):
        xd = xd.reshape(-1, xd.shape[-1]).astype(jnp.float32)
        xp = xp.reshape(-1, xp.shape[-1]).astype(jnp.float32)
        wx = wx.reshape(-1, wx.shape[-1]).astype(jnp.float32)
        dG = jax.lax.psum(xp.T @ xp, data_axis)
        dC = jax.lax.psum(xd.T @ xp, data_axis)
        dH = jax.lax.psum(xd.T @ xd, data_axis)
        dh = jax.lax.psum(jnp.sum(wx * wx), data_axis)
        dn = jax.lax.psum(jnp.float32(xd.shape[0]), data_axis)
        return G + dG, C + dC, H + dH, h + dh, cnt + dn

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None), P(None, None), P(None, None), P(), P(),
                  P(data_axis), P(data_axis), P(data_axis)),
        out_specs=(P(None, None), P(None, None), P(None, None), P(), P()),
        check_rep=True)  # MESH001: explicit contract
    G, C, H, h, cnt = fn(stats.G, stats.C, stats.H, stats.h, stats.count,
                         x_dense, x_pruned, wx_dense)
    return GramStats(G=G, C=C, H=H, h=h, count=cnt)
