"""GSPMD sharding rules: DP x TP (x EP for MoE) across all families.

Weights are model-layout (in, out).  Tensor-parallel convention (Megatron
column->row pairing, collective-minimal):

* first matmul of a block (wq/wk/wv, gate/up/fc1, wx/wy, in_proj) shards
  its OUTPUT dim over "model"  -> activations become model-sharded;
* second matmul (wo, down/fc2, out_proj) shards its INPUT dim over
  "model" -> the products reduce over the model axis (one all-reduce per
  block, inserted by GSPMD);
* embeddings shard the vocab dim; logits reduce at the loss;
* MoE experts shard the EXPERT dim over "model" (expert parallelism) —
  the per-token top-k dispatch becomes an all-to-all;
* vectors (norms, biases, A_log, conv kernels) replicate.

The batch dim of every input shards over ("pod", "data") — the pod axis
is an outer DP axis by default; pipeline parallelism over pods is the
optional alternative in distributed/pipeline.py.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils.tree import tree_map_with_path

# (regex on "/"-joined path, spec builder(ndim, dp_axes) -> PartitionSpec)
# NOTE: order matters — first match wins.
#
# MoE layout note (§Perf iteration 1): sharding the EXPERT dim over
# "model" (classic EP) forces GSPMD to all-gather the GLOBAL token set
# onto every device before the ragged grouped-GEMM — measured ~650x flop
# overcount and tens of GB of all-gather per layer on mixtral train_4k.
# TP-WITHIN-EXPERT (shard each expert's hidden dim, experts replicated)
# keeps tokens local: w_gate/w_up shard d_ff (col), w_down shards d_ff
# (row), one all-reduce per FFN — same collective shape as the dense
# blocks.  Tokens stay data-sharded end to end.
_RULES: Sequence[Tuple[str, str]] = (
    (r".*/w_(gate|up)$", "expert_col"),
    (r".*/w_down$", "expert_row"),
    (r".*/router$", "replicate"),
    # block-entry matmuls: column parallel (shard out)
    (r".*/(wq|wk|wv|gate|up|fc1|wx|wy|wa|wi|in_proj)$", "col"),
    # block-exit matmuls: row parallel (shard in)
    (r".*/(wo|down|fc2|out_proj)$", "row"),
    # embeddings: shard vocab rows
    (r".*(^|/)embed$", "vocab"),
    (r".*head$", "col"),
    (r".*pos_embed$", "replicate"),
)


def _spec_for(kind: str, ndim: int, stacked: bool) -> P:
    """Translate a rule kind to a PartitionSpec, accounting for a leading
    layer-stack dim."""
    lead: Tuple = (None,) if stacked else ()
    if kind == "col":      # (in, out) -> shard out
        return P(*lead, None, "model")
    if kind == "row":      # (in, out) -> shard in
        return P(*lead, "model", None)
    if kind == "vocab":
        return P(*lead, "model", None)
    if kind == "expert_col":   # (E, d, ff): shard ff (TP within expert)
        if ndim == 4:          # stacked layers: (L, E, d, ff)
            return P(None, None, None, "model")
        return P(None, None, "model")
    if kind == "expert_row":   # (E, ff, d): shard ff
        if ndim == 4:
            return P(None, None, "model", None)
        return P(None, "model", None)
    return P()             # replicate


def param_specs(params: Any) -> Any:
    """Pytree of PartitionSpec matching ``params`` via the path rules.

    Detects layer-stacked leaves by path prefix ("layers"/"enc_layers"/
    "dec_layers" subtrees carry a leading L dim unless the path has an
    explicit integer segment, e.g. rglru's "layers/3/...")."""

    def visit(path: str, leaf: Any) -> P:
        stacked = bool(re.match(r".*(^|/)(layers|enc_layers|dec_layers)/", path + "/")) \
            and not re.search(r"/(\d+)/", path)
        ndim = getattr(leaf, "ndim", 0)
        for pattern, kind in _RULES:
            if re.fullmatch(pattern, path):
                spec = _spec_for(kind, ndim, stacked)
                if len([s for s in spec]) > ndim:
                    return P()
                return spec
        return P()

    return tree_map_with_path(visit, params)


def batch_specs(batch: Any, dp_axes: Tuple[str, ...] = ("data",)) -> Any:
    """Shard the leading batch dim of every input over the DP axes."""
    return jax.tree_util.tree_map(lambda x: P(dp_axes), batch)


def state_specs(serve_state: Any, dp_axes: Tuple[str, ...] = ("data",),
                batch_axis_index: int = 1, shard_cache_seq: bool = True) -> Any:
    """Serving state: layer-stacked caches (L, B, ...) shard B over DP.

    ``shard_cache_seq`` (§Perf iteration 4, flash-decode style context
    parallelism): 5-D KV caches (L, B, S_cache, H, hd) additionally shard
    the SEQUENCE dim over "model".  Decode attention contracts over the
    cache length, so each model shard scores its local KV chunk and the
    softmax/PV combine reduces over the axis — the per-step collectives
    become O(B*heads) instead of O(cache), and per-device cache memory
    drops by the TP degree.  (With head counts that don't divide the
    model axis — MQA/GQA small-kv archs — head-sharding is impossible,
    making this THE way to TP a decode cache.)
    """

    def visit(path: str, leaf: Any) -> P:
        nd = getattr(leaf, "ndim", 0)
        if nd >= 2:
            spec: list = [None] * nd
            spec[batch_axis_index] = dp_axes
            if shard_cache_seq and nd == 5:
                spec[2] = "model"      # (L, B, S_cache, H, hd)
            return P(*spec)
        return P()

    return tree_map_with_path(visit, serve_state)


def _fit_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop axis assignments whose mesh size doesn't divide the dim
    (e.g. odd vocab sizes like whisper's 51865 -> replicated embed; at
    real scale one would pad the vocab to a multiple of the TP degree)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if size and shape[i] % size == 0 else None)
    return P(*out)


def make_shardings(mesh: Mesh, specs: Any, shapes: Any = None) -> Any:
    """PartitionSpec tree -> NamedSharding tree; with ``shapes`` (matching
    tree of arrays/ShapeDtypeStructs) non-divisible dims are replicated."""
    if shapes is None:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_map(
        lambda s, x: NamedSharding(mesh, _fit_spec(mesh, s, x.shape)),
        specs, shapes, is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(pspecs: Any) -> Any:
    """Adam moments shard exactly like their parameters."""
    return pspecs
