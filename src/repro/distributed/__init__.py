"""Distribution layer: GSPMD sharding rules, row-parallel FISTA,
pipeline parallelism over pods, int8 gradient compression."""
from repro.distributed.sharding import (batch_specs, make_shardings,
                                        param_specs, state_specs)

__all__ = ["batch_specs", "make_shardings", "param_specs", "state_specs"]
