"""Distribution layer: the mesh executor (the one sharded substrate for
prune/eval/serve), GSPMD sharding rules, row-parallel FISTA, pipeline
parallelism over pods, int8 gradient compression."""
from repro.distributed.executor import MeshConfig, MeshExecutor
from repro.distributed.sharding import (batch_specs, make_shardings,
                                        param_specs, state_specs)

__all__ = ["MeshConfig", "MeshExecutor", "batch_specs", "make_shardings",
           "param_specs", "state_specs"]
