"""End-to-end driver: train a small LM, prune it with every method, report
the perplexity table (the paper's Tables 1/2 protocol, CPU scale).

    PYTHONPATH=src python examples/end_to_end_prune.py [--steps 300]

Scale note: the same path runs any assigned architecture at full size on
real hardware via ``python -m repro.launch.prune --arch <id> --full``; the
CPU default uses the OPT-125M-family tiny proxy from the paper's own
model family.
"""
import argparse

from repro import api
from repro.data import CorpusConfig, MarkovCorpus
from repro.models.registry import model_def
from repro.train import AdamWConfig, TrainConfig, Trainer, evaluate_ppl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--sparsity", default="50%")
    args = ap.parse_args()

    from repro.configs.opt125m_proxy import tiny_config
    model = model_def(tiny_config())
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=11))

    print(f"training dense model ({args.steps} steps)...")
    tr = Trainer(model, corpus, TrainConfig(
        steps=args.steps, batch=16, seq=64, log_every=100,
        optim=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)))
    tr.run()
    dense_ppl = evaluate_ppl(model, tr.params, corpus, 8, 64, 6)
    print(f"dense ppl = {dense_ppl:.3f}\n")

    # one PruneRecipe per method — every registered solver flows through
    # the same repro.api.prune entry point (DESIGN.md §7)
    print(f"{'method':>10} | {'ppl':>8} | {'mean rel err':>12}")
    for method in ("magnitude", "wanda", "sparsegpt", "admm", "fista"):
        solver_kw = {"warm_start": "sparsegpt", "fista_iters": 20,
                     "eps": 1e-6, "max_outer": 12} if method == "fista" else {}
        recipe = api.PruneRecipe(
            method=method, sparsity=args.sparsity, solver=solver_kw,
            calibration={"num_sequences": 32, "seq_len": 64, "batch_size": 8})
        calib = api.calibration_for(recipe, corpus)
        pruned, reports, _ = api.prune(model, tr.params, calib, recipe)
        ppl = evaluate_ppl(model, pruned, corpus, 8, 64, 6)
        rel = sum(r.rel_error for r in reports) / max(len(reports), 1)
        print(f"{method:>10} | {ppl:8.3f} | {rel:12.4f}")


if __name__ == "__main__":
    main()
