"""Sparse serving: 2:4-prune a model, pack the weights, decode with the
spmm24 Pallas kernel path, and account the bandwidth win.

    PYTHONPATH=src python examples/sparse_serving.py

TPU adaptation of the paper's 2:4 motivation: no sparse MXU on TPU, so
the payoff is decode-time HBM traffic — packed weights move 0.625x the
bytes (DESIGN.md §2).
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pruner import PrunerConfig
from repro.core.sequential import SequentialConfig, prune_model
from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import model_def
from repro.serve import Engine, ServeConfig, pack_tree
from repro.train import AdamWConfig, TrainConfig, Trainer


def main():
    from repro.configs.opt125m_proxy import tiny_config
    model = model_def(tiny_config())
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=7))

    print("training briefly so generations aren't pure noise...")
    tr = Trainer(model, corpus, TrainConfig(
        steps=120, batch=16, seq=64, log_every=60,
        optim=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=120)))
    tr.run()

    print("pruning to 2:4 with FISTAPruner...")
    calib = calibration_batches(corpus, CalibConfig(num_sequences=16,
                                                    seq_len=64, batch_size=8))
    cfg = SequentialConfig(spec=SparsitySpec.parse("2:4"), method="fista",
                           pruner=PrunerConfig(fista_iters=10, max_outer=4))
    pruned, _ = prune_model(model, tr.params, calib, cfg)

    packed, stats = pack_tree(pruned)
    print(f"packed {stats['packed_ops']} operators: "
          f"{stats['dense_bytes']/1e6:.2f} MB dense bf16 -> "
          f"{stats['packed_bytes']/1e6:.2f} MB packed "
          f"({stats['packed_bytes']/stats['dense_bytes']:.3f}x weight traffic)")

    prompt = jnp.asarray(next(corpus.batches(2, 16))[1][:, :16], jnp.int32)
    # sparse="dense" is the fallback flag; the default sparse="auto" would
    # detect the 2:4 checkpoint and pack it (losslessly) by itself
    dense_out = Engine(model, pruned,
                       ServeConfig(max_new_tokens=12, sparse="dense")).generate(prompt)
    auto = Engine(model, pruned, ServeConfig(max_new_tokens=12))
    print("auto-detected:", auto.sparse_stats)
    auto_out = auto.generate(prompt)
    packed_out = Engine(model, packed, ServeConfig(max_new_tokens=12)).generate(prompt)
    print("dense-weight decode  :", dense_out[0].tolist())
    print("auto-packed decode   :", auto_out[0].tolist())
    print("bf16-packed decode   :", packed_out[0].tolist())
    print("auto == dense (bitwise fp32 logits):",
          bool(np.array_equal(dense_out, auto_out)))
    print("bf16 == dense:", bool(np.array_equal(dense_out, packed_out)))


if __name__ == "__main__":
    main()
