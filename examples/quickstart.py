"""Quickstart: prune one linear operator with FISTAPruner in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Walks the core API: Gram statistics -> Algorithm 1 -> rounding —
exactly the per-operator path of the paper (Fig. 1), no model needed.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import gram
from repro.core.pruner import PrunerConfig, prune_operator
from repro.core.solvers import get_solver
from repro.core.sparsity import SparsitySpec, sparsity

# a synthetic "linear operator + calibration activations" problem:
# W (out=256, in=128) paper layout; X (in, tokens) with CORRELATED features
# (a decaying spectrum, like real LLM activations) — the regime where
# convex optimization beats heuristic masks.  With isotropic X all methods
# provably coincide (the LASSO prox = magnitude mask there).
rng = np.random.default_rng(0)
m, n, tokens = 256, 128, 4096
W = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
mix = rng.normal(size=(n, n)) * (0.95 ** np.arange(n))[None, :]  # spectrum decay
X = jnp.asarray((mix @ rng.normal(size=(n, tokens))).astype(np.float32))

# 1. accumulate Gram statistics (streaming; here X* = X — no upstream pruning)
stats = gram.accumulate(gram.init_stats(n), X.T, X.T, (W @ X).T)

# 2. run Algorithm 1 (FISTA + rounding + adaptive lambda bisection)
spec = SparsitySpec.parse("2:4")
res = prune_operator(W, stats, spec,
                     PrunerConfig(warm_start="sparsegpt", fista_iters=20,
                                  eps=1e-6, max_outer=16))

print(f"sparsity        : {float(sparsity(res.weight)):.3f} (target {1-spec.target_density})")
print(f"relative error  : {res.rel_error:.4f}  (||W*X - WX||_F / ||WX||_F)")
print(f"final lambda    : {res.lam:.3e}  after {res.outer_iters} outer iters")

# 3. compare against other registered solvers on the same statistics
#    (every method is a LayerSolver — see core/solvers.py / DESIGN.md §7)
for method in ("magnitude", "wanda", "sparsegpt", "admm"):
    r = get_solver(method).solve(W, stats, spec)
    print(f"{method:>10} err : {r.rel_error:.4f}")
print(f"{'fista':>10} err : {res.rel_error:.4f}   <- should beat the one-shots")
