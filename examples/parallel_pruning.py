"""Parallel + fault-tolerant pruning (paper Sec. 3.4 at system level).

    PYTHONPATH=src python examples/parallel_pruning.py

Demonstrates the production path: decoder layers are independent pruning
units pulled from a work queue by several workers; a unit failure is
retried; completed units land in the crc-verified checkpoint store; a
"restarted job" resumes without recomputing anything.
"""
import shutil
import tempfile
import threading

import jax

from repro.core.driver import parallel_prune
from repro.core.pruner import PrunerConfig
from repro.core.scheduler import SchedulerConfig
from repro.core.sequential import SequentialConfig
from repro.core.sparsity import SparsitySpec
from repro.data import CalibConfig, CorpusConfig, MarkovCorpus, calibration_batches
from repro.models.registry import model_def


def main():
    from repro.configs.opt125m_proxy import tiny_config
    model = model_def(tiny_config())
    params = model.init(jax.random.PRNGKey(0))
    corpus = MarkovCorpus(CorpusConfig(vocab=model.cfg.vocab, seed=3))
    calib = calibration_batches(corpus, CalibConfig(num_sequences=16,
                                                    seq_len=48, batch_size=8))
    cfg = SequentialConfig(spec=SparsitySpec(ratio=0.5), method="fista",
                           pruner=PrunerConfig(fista_iters=10, max_outer=4))
    ckpt_dir = tempfile.mkdtemp(prefix="prune_units_")

    # ---- run 1: three workers + one injected transient failure ------------
    import repro.core.sequential as seq
    orig, failed = seq.prune_unit, {"done": False}
    lock = threading.Lock()

    def flaky(model_, spec, *a, **kw):
        with lock:
            if spec.name == "layer001" and not failed["done"]:
                failed["done"] = True
                raise RuntimeError("injected node failure")
        return orig(model_, spec, *a, **kw)

    seq.prune_unit = flaky
    try:
        pruned, reports, stats = parallel_prune(
            model, params, calib, cfg,
            SchedulerConfig(workers=3, max_retries=2, checkpoint_dir=ckpt_dir))
    finally:
        seq.prune_unit = orig
    print(f"run 1: {stats['completed']} units pruned with 3 workers; "
          f"attempts per unit: {stats['attempts']}")

    # ---- run 2: simulated restart — everything resumes from checkpoints ---
    pruned2, reports2, stats2 = parallel_prune(
        model, params, calib, cfg,
        SchedulerConfig(workers=3, checkpoint_dir=ckpt_dir))
    print(f"run 2 (restart): {stats2['completed']} units resumed, "
          f"attempts: {stats2['attempts']} (all zero => pure resume)")
    shutil.rmtree(ckpt_dir)


if __name__ == "__main__":
    main()
